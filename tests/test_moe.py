"""MoE dispatch: capacity semantics, combine correctness, aux-loss behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.layers import init_tree
from repro.models.moe import expert_capacity, moe_forward, moe_specs

KEY = jax.random.PRNGKey(11)


def tiny_moe_cfg(n_experts=4, top_k=2):
    base = get_config("kimi-k2-1t-a32b").reduced()
    return dataclasses.replace(
        base, dtype="float32", d_model=32,
        moe=dataclasses.replace(base.moe, n_experts=n_experts, top_k=top_k,
                                d_ff_expert=16, n_shared_experts=0,
                                first_k_dense=0))


def dense_moe_oracle(cfg, p, x):
    """Compute every expert for every token, combine with renormalized top-k gates."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d).astype(jnp.float32)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, m.top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xt, p["w_up"].astype(jnp.float32))
    h = jax.nn.silu(g) * u
    out_all = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(jnp.float32))
    y = jnp.zeros((T, d))
    for k in range(m.top_k):
        y = y + gv[:, k, None] * jnp.take_along_axis(
            out_all, gi[:, k][:, None, None], axis=1)[:, 0]
    return y.reshape(B, S, d)


def test_moe_matches_dense_oracle_when_no_drops():
    cfg = tiny_moe_cfg()
    p = init_tree(moe_specs(cfg, jnp.float32), KEY)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    # capacity_factor huge -> nothing dropped -> exact match with dense oracle
    y, aux = moe_forward(cfg, p, x, capacity_factor=100.0)
    exp = dense_moe_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp), atol=1e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens():
    cfg = tiny_moe_cfg(n_experts=2, top_k=1)
    p = init_tree(moe_specs(cfg, jnp.float32), KEY)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model))
    y_full, _ = moe_forward(cfg, p, x, capacity_factor=100.0)
    y_tight, _ = moe_forward(cfg, p, x, capacity_factor=0.25)
    # tight capacity must change (drop) some outputs
    assert np.abs(np.asarray(y_full - y_tight)).max() > 1e-5
    # dropped tokens produce zeros (plus no shared/dense path in this cfg)
    norms_tight = np.linalg.norm(np.asarray(y_tight), axis=-1).ravel()
    assert (norms_tight < 1e-6).sum() > 0


def test_moe_aux_loss_prefers_balance():
    cfg = tiny_moe_cfg(n_experts=4, top_k=1)
    p = init_tree(moe_specs(cfg, jnp.float32), KEY)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model))
    # collapse the router to a single expert -> aux loss must rise
    p_collapsed = dict(p, router=p["router"] * 0 + jnp.array(
        [[10.0, 0, 0, 0]] * cfg.d_model))
    _, aux_bal = moe_forward(cfg, p, x, capacity_factor=2.0)
    _, aux_col = moe_forward(cfg, p_collapsed, x, capacity_factor=2.0)
    assert float(aux_col) > float(aux_bal)


@settings(max_examples=60, deadline=None)
@given(
    T=st.integers(min_value=1, max_value=4096),
    E=st.sampled_from([2, 4, 16, 128, 384]),
    k=st.integers(min_value=1, max_value=8),
    cf=st.floats(min_value=0.1, max_value=4.0),
)
def test_property_capacity_bounds(T, E, k, cf):
    C = expert_capacity(T, E, k, cf)
    assert 8 <= C <= max(T, 8)          # lane-padded, never exceeds token count
    import math
    needed = math.ceil(T * k * cf / E)
    assert C >= min(needed, max(T, 8)) - 8   # close to the demanded capacity


def test_arctic_dense_residual_always_on():
    cfg = dataclasses.replace(get_config("arctic-480b").reduced(), dtype="float32",
                              d_model=32)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, d_ff_expert=16, d_ff_dense=16))
    p = init_tree(moe_specs(cfg, jnp.float32), KEY)
    assert "dense" in p
    x = jax.random.normal(KEY, (1, 8, cfg.d_model))
    # zero all experts: output must still be nonzero through the dense residual
    p_zero = jax.tree.map(lambda a: a, p)
    p_zero = dict(p_zero, w_down=p["w_down"] * 0)
    y, _ = moe_forward(cfg, p_zero, x, capacity_factor=2.0)
    assert np.abs(np.asarray(y)).max() > 1e-6
