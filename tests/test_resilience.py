"""The resilience layer: deadlines, retry budgets, breakers, quarantine,
admission control, and integrity-verified restores.

Unit tests pin the primitives in repro.core.resilience; the integration
tests drive them through the real dispatcher/scheduler on a virtual clock
(flaky host -> breaker opens -> quarantine -> half-open probe revives) and
through the real restore path (a lying peer's chunks are re-hashed, dropped,
and transparently re-fetched from the store — wrong bytes are never
returned). The timer, boot-claim, and read-ahead backpressure regressions
from this PR's satellites live here too.
"""
import logging
import sys
import threading
import time
import types
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.blobstore import (
    ChunkIntegrityError,
    ChunkStore,
    delta_restore,
)
from repro.core.boot import (
    ENGINE,
    BootPlan,
    Finalize,
    Stage,
    TRACK_PROGRAM,
    TRACK_WEIGHTS,
    streamed_device_put,
)
from repro.core.dispatcher import Dispatcher
from repro.core.metrics import Timeline
from repro.core.resilience import (
    CLOSED,
    OPEN,
    AdmissionController,
    AdmissionRejected,
    BackoffPolicy,
    BreakerBoard,
    CircuitBreaker,
    Deadline,
    DeadlineExceeded,
    ResilienceConfig,
    RetryBudget,
)
from repro.core.scheduler import CacheDirectory, HostArtifactCache, SchedulerConfig
from repro.core.simclock import VirtualClock
from repro.core.snapshot import SnapshotStore
from repro.core.timerwheel import DeadlineTimer

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.bench_scale import SimCluster, XlaRuntimeError  # noqa: E402


# ------------------------------------------------------------------ deadlines


def test_deadline_remaining_expired_and_check_on_virtual_clock():
    clock = VirtualClock()
    d = Deadline.after(1.0, clock=clock)
    assert d.remaining() == pytest.approx(1.0)
    assert not d.expired()
    d.check("early")                                   # no raise while live
    clock.run_until(1.5)
    assert d.expired()
    assert d.remaining() == pytest.approx(-0.5)
    with pytest.raises(DeadlineExceeded, match="at boot"):
        d.check("boot")


def test_backoff_grows_caps_and_jitters_deterministically():
    import random

    p = BackoffPolicy(base_s=0.1, factor=2.0, cap_s=0.5, jitter=0.5)
    rng = random.Random(0)
    delays = [p.delay(n, rng) for n in range(6)]
    raw = [min(0.5, 0.1 * 2.0 ** n) for n in range(6)]
    for d, r in zip(delays, raw):
        assert r * 0.5 <= d <= r                       # jitter only shrinks
    assert delays[-1] <= 0.5                           # capped
    rng2 = random.Random(0)                            # same seed, same delays
    assert delays == [p.delay(n, rng2) for n in range(6)]


def test_retry_budget_floor_deposits_and_denial():
    b = RetryBudget(fraction=0.5, floor=2.0, cap=3.0)
    assert b.try_spend() and b.try_spend()             # the always-there floor
    assert not b.try_spend()
    assert b.denied == 1
    for _ in range(10):
        b.deposit()                                    # 10 x 0.5, capped at 3
    assert b.tokens == pytest.approx(3.0)
    assert all(b.try_spend() for _ in range(3))
    assert not b.try_spend()
    assert b.deposits == 10 and b.spent == 5


# ------------------------------------------------------------------- breakers


def test_breaker_opens_after_consecutive_failures_and_probe_revives():
    t = [0.0]
    br = CircuitBreaker(failures=3, cooldown_s=10.0, probes=1,
                        now_fn=lambda: t[0])
    assert br.state == CLOSED and br.allow()
    br.record_failure()
    br.record_success()                                # success resets streak
    br.record_failure()
    br.record_failure()
    assert br.state == CLOSED
    br.record_failure()                                # third consecutive
    assert br.state == OPEN and br.opens == 1
    assert not br.allow()                              # cooling down
    t[0] = 10.1
    assert br.gate() == "probe"                        # half-open: one slot
    assert not br.allow()                              # slots exhausted
    br.record_success()
    assert br.state == CLOSED and br.probe_revivals == 1


def test_breaker_probe_failure_reopens_for_a_fresh_cooldown():
    t = [0.0]
    br = CircuitBreaker(failures=1, cooldown_s=10.0, probes=1,
                        now_fn=lambda: t[0])
    br.record_failure()
    assert br.state == OPEN
    t[0] = 10.1
    assert br.allow()                                  # the probe
    br.record_failure()                                # probe failed
    assert br.state == OPEN and br.opens == 2
    assert not br.allow()                              # new cooldown from now
    t[0] = 20.2
    assert br.allow()


def test_breaker_release_probe_returns_the_unused_slot():
    t = [0.0]
    br = CircuitBreaker(failures=1, cooldown_s=1.0, probes=1,
                        now_fn=lambda: t[0])
    br.record_failure()
    t[0] = 1.1
    assert br.gate() == "probe"
    assert br.gate() == "blocked"                      # slot taken
    br.release_probe()                                 # considered, not chosen
    assert br.gate() == "probe"                        # slot handed back


def test_breaker_board_allows_unknown_targets_without_materializing():
    board = BreakerBoard()
    assert board.allow("host:9")
    assert board.summary()["targets"] == 0             # never materialized
    board.record("host:9", False)
    assert board.summary()["targets"] == 1


def test_breaker_board_bind_clock_retrofits_existing_breakers():
    board = BreakerBoard(failures=1, cooldown_s=5.0)
    board.breaker("host:0")                            # materialized pre-bind
    clock = VirtualClock()
    board.bind_clock(clock)
    board.record_host(0, ok=False)
    assert not board.allow_host(0)
    assert board.summary()["open_now"] == ["host:0"]
    clock.run_until(5.1)                               # cooldown on NEW clock
    assert board.allow_host(0)
    assert board.summary()["half_open_now"] == ["host:0"]


# ------------------------------------------------------------------ admission


def test_admission_brownout_enters_and_exits_with_hysteresis():
    cfg = ResilienceConfig(brownout_hi=2.0, brownout_lo=1.0)
    adm = AdmissionController(cfg, capacity_slots=2)
    for _ in range(4):
        adm.try_admit()
    assert not adm.brownout
    adm.try_admit()                                    # sees 4 >= 2 x 2
    assert adm.brownout
    assert adm.summary()["brownout_entries"] == 1.0
    adm.release()
    adm.release()
    assert adm.brownout                                # 3 > 2: still browned
    adm.release()                                      # 2 <= 2 x 1.0: exit
    assert not adm.brownout


def test_admission_sheds_expired_deadline_even_when_idle():
    clock = VirtualClock()
    adm = AdmissionController(ResilienceConfig(), capacity_slots=4)
    with pytest.raises(AdmissionRejected):
        adm.try_admit(Deadline.after(0.0, clock=clock))
    assert adm.summary()["shed"] == 1.0
    adm.try_admit(Deadline.after(10.0, clock=clock))   # feasible: admitted
    assert adm.summary()["admitted"] == 1.0


def test_admission_brownout_sheds_below_observed_service_time():
    clock = VirtualClock()
    cfg = ResilienceConfig(brownout_hi=1.0, brownout_lo=0.0)
    adm = AdmissionController(cfg, capacity_slots=1)
    adm.try_admit()
    adm.try_admit()                                    # 1 >= 1: brownout
    assert adm.brownout
    adm.release(2.0)                                   # observed e2e: 2 s
    with pytest.raises(AdmissionRejected, match="brownout"):
        adm.try_admit(Deadline.after(1.0, clock=clock))
    adm.try_admit(Deadline.after(5.0, clock=clock))    # beats the ewma: in
    assert adm.summary()["shed"] == 1.0


# ------------------------------------- timer survives raising callbacks (sat 1)


def test_timer_survives_raising_callback_real_clock(caplog):
    timer = DeadlineTimer("resilience-test-real")
    fired = threading.Event()

    def bad():
        raise ValueError("boom")

    with caplog.at_level(logging.ERROR, logger="repro.core.timerwheel"):
        timer.schedule(0.01, bad)
        timer.schedule(0.03, fired.set)
        assert fired.wait(5.0)                         # worker outlived `bad`
    timer.close()
    assert any("raised; continuing" in r.getMessage() for r in caplog.records)


def test_timer_survives_raising_callback_virtual_clock(caplog):
    clock = VirtualClock()
    timer = DeadlineTimer("resilience-test-virtual", clock=clock)
    fired = []

    def bad():
        raise ValueError("boom")

    timer.schedule(0.1, bad)
    timer.schedule(0.2, lambda: fired.append(1))
    with caplog.at_level(logging.ERROR, logger="repro.core.timerwheel"):
        clock.run_until_idle()                         # event loop must survive
    timer.close()
    assert fired == [1]
    assert any("raised; continuing" in r.getMessage() for r in caplog.records)


# ---------------------------------------------- boot: claim + deadlines (sat 2)


class _SleepStage(Stage):
    def __init__(self, name, track, seconds, sets=()):
        self.name, self.track, self.seconds, self.sets = name, track, seconds, sets

    def run(self, ctx):
        time.sleep(self.seconds)
        for attr, value in self.sets:
            setattr(ctx, attr, value)


def _fake_dep():
    return types.SimpleNamespace(image=types.SimpleNamespace(key="img-res"))


def test_boot_claim_timeout_names_last_completed_stage():
    release = threading.Event()

    class _Blocked(Stage):
        name, track = "restore_weights_host", TRACK_WEIGHTS

        def run(self, ctx):
            ctx.params = {}
            release.wait(10.0)

    plan = BootPlan([
        _SleepStage("deserialize_program", TRACK_PROGRAM, 0.0,
                    sets=[("program", lambda p, t: t)]),
        _Blocked(),
        Finalize(),
    ])
    handle = ENGINE.launch(plan, _fake_dep(), driver_name="t")
    try:
        with pytest.raises(TimeoutError,
                           match="last completed stage: deserialize_program"):
            handle.claim(timeout=0.3)
    finally:
        release.set()
    for _ in range(200):
        if handle.done():
            break
        time.sleep(0.01)
    handle.cancel()                                    # dispose the executor


def test_agent_claim_timeout_is_configurable():
    from repro.core.agent import Agent
    from repro.core.metrics import Recorder, ResidencyTracker

    agent = Agent(Recorder(), ResidencyTracker(), claim_timeout_s=0.25)
    assert agent.claim_timeout_s == 0.25


def test_deadline_aborts_boot_at_stage_boundary():
    tl = Timeline()
    tl.deadline = Deadline.after(0.05)
    plan = BootPlan([
        _SleepStage("deserialize_program", TRACK_PROGRAM, 0.0,
                    sets=[("program", lambda p, t: t)]),
        _SleepStage("restore_weights_host", TRACK_WEIGHTS, 0.15,
                    sets=[("params", {})]),
        _SleepStage("restore_weights_device", TRACK_WEIGHTS, 0.0),
        Finalize(),
    ])
    with pytest.raises(DeadlineExceeded, match="restore_weights_device"):
        ENGINE.execute(plan, _fake_dep(), tl, driver_name="t")


# --------------------------------------- streamed put: backpressure (sat 3)


def test_streamed_device_put_backpressure_never_drops_chunks(monkeypatch):
    """prefetch=1 queue + a consumer slower than the producer: every put hits
    queue.Full and must retry, never drop. Exact equality of every leaf is
    the proof — a silently dropped chunk would leave a None/stale leaf."""
    tree = {f"leaf{i:02d}": np.full(64, i, np.float32) for i in range(12)}
    real_put = jax.device_put

    def slow_put(x, *args, **kwargs):
        time.sleep(0.12)                   # > the producer's 0.1 s put timeout
        return real_put(x, *args, **kwargs)

    monkeypatch.setattr(jax, "device_put", slow_put)
    out = streamed_device_put(tree, chunk_bytes=256, prefetch=1)
    for key, val in tree.items():
        np.testing.assert_array_equal(np.asarray(out[key]), val)


def test_streamed_device_put_aborts_mid_stream_on_deadline(monkeypatch):
    tree = {f"leaf{i}": np.full(32, i, np.float32) for i in range(8)}
    real_put = jax.device_put

    def slow_put(x, *args, **kwargs):
        time.sleep(0.05)
        return real_put(x, *args, **kwargs)

    monkeypatch.setattr(jax, "device_put", slow_put)
    with pytest.raises(DeadlineExceeded, match="device stream"):
        streamed_device_put(tree, chunk_bytes=128, prefetch=1,
                            deadline=Deadline.after(0.08))


# -------------------------------------------------- integrity-verified restores


def _tree(seed=0, n=6, leaf_bytes=256):
    rng = np.random.default_rng(seed)
    return {f"layer{i}": rng.standard_normal(leaf_bytes // 8)
            for i in range(n)}


def test_chunkstore_get_raises_on_persistent_corruption(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=64)
    cid = store.put(b"x" * 64)
    store._path(cid).write_bytes(b"y" * 64)            # rot the stored bytes
    with pytest.raises(ChunkIntegrityError):
        store.get(cid)
    assert store.integrity_failures == 1
    assert store.get(cid, verify=False) == b"y" * 64   # explicit escape hatch


def _paired_caches():
    cfg = SchedulerConfig()
    directory = CacheDirectory()
    warm = HostArtifactCache(0, cfg, directory)
    cold = HostArtifactCache(1, cfg, directory)
    return warm, cold, {0: warm, 1: cold}


def test_delta_restore_refetches_poisoned_peer_chunks(tmp_path):
    warm, cold, by_id = _paired_caches()

    def lying_peer(key, cids, requester):
        got = {}
        for hid, cache in by_id.items():
            if hid != requester:
                got.update(cache.snapshots.chunks_for(cids))
        # every byte the peer serves is garbage of the right length
        return {cid: b"\x00" * len(data) for cid, data in got.items()}

    warm.peer_chunks = cold.peer_chunks = lying_peer
    blobs = ChunkStore(tmp_path / "blobs", chunk_bytes=64)
    store = SnapshotStore(tmp_path / "snaps", blobs=blobs)
    tree = _tree()
    store.save("v1", tree)
    delta_restore(store, "v1", warm)                   # host 0 publishes v1

    got, stats = delta_restore(store, "v1", cold)      # peer serves only lies
    for key, val in tree.items():                      # NEVER the wrong bytes
        np.testing.assert_array_equal(np.asarray(got[key]), np.asarray(val))
    assert stats.chunks_refetched > 0
    assert stats.chunks_rehashed >= stats.chunks_refetched
    assert stats.bytes_from_peer == 0                  # lies un-accounted
    assert stats.bytes_from_store == stats.bytes_total


def test_peer_breaker_opens_after_repeated_lying(tmp_path):
    warm, cold, by_id = _paired_caches()
    board = BreakerBoard(failures=2, cooldown_s=60.0)
    warm.breakers = cold.breakers = board

    def lying_peer(key, cids, requester):
        got = {}
        for hid, cache in by_id.items():
            if hid != requester:
                got.update(cache.snapshots.chunks_for(cids))
        return {cid: b"\x00" * len(data) for cid, data in got.items()}

    warm.peer_chunks = cold.peer_chunks = lying_peer
    blobs = ChunkStore(tmp_path / "blobs", chunk_bytes=64)
    store = SnapshotStore(tmp_path / "snaps", blobs=blobs)
    trees = {f"v{i}": _tree(seed=i) for i in range(3)}
    for key, tree in trees.items():
        store.save(key, tree)
        delta_restore(store, key, warm)                # host 0 holds them all

    delta_restore(store, "v0", cold)                   # lie #1: recorded
    delta_restore(store, "v1", cold)                   # lie #2: breaker opens
    assert not board.allow("peer")
    got, stats = delta_restore(store, "v2", cold)      # peer tier bypassed
    for key, val in trees["v2"].items():
        np.testing.assert_array_equal(np.asarray(got[key]), np.asarray(val))
    assert stats.bytes_from_peer == 0
    assert stats.chunks_refetched == 0                 # nothing to drop
    assert cold.peer_fetches == 2                      # v2 never asked a peer


# ------------------------------------------- dispatcher integration (virtual)


class _StubAgent:
    """Scale-harness agent stand-in: fixed charge, outcome scripted per host."""

    def __init__(self, clock, outcome, charge_s=0.01):
        self.clock = clock
        self.outcome = outcome
        self.charge_s = charge_s
        self.calls = []

    def handle(self, host, dep, tokens, driver_name, tl, label=None,
               preboot=None):
        self.calls.append(host.host_id)
        host.charge(self.charge_s)
        t0 = self.clock.now()
        tl.t_dispatch = tl.t_start_begin = tl.t_exec_begin = t0
        tl.t_done = t0 + self.charge_s
        return self.outcome(host)


def test_flaky_host_quarantined_then_probe_revived():
    clock = VirtualClock()
    cluster = SimCluster(clock, n_hosts=2, slots_per_host=2,
                         scheduler=SchedulerConfig(breaker_failures=3,
                                                   breaker_cooldown_s=5.0))
    flaky_hosts = {0}

    def outcome(host):
        if host.host_id in flaky_hosts:
            raise XlaRuntimeError("flaky host")
        return "ok"

    agent = _StubAgent(clock, outcome)
    disp = Dispatcher(cluster, agent, hedging=False, max_retries=4,
                      clock=clock)
    board = cluster.scheduler.breakers

    def settle_one():
        fut = disp.submit(None, [1], "sim")
        clock.run_until_idle()
        assert fut.result(timeout=0) == "ok"           # retries route around

    for _ in range(40):
        settle_one()
        if board.breaker("host:0").state == OPEN:
            break
    else:
        pytest.fail("host:0 breaker never opened")

    mark = len(agent.calls)
    for _ in range(5):
        settle_one()
    assert 0 not in agent.calls[mark:]                 # quarantined out
    assert cluster.scheduler.quarantine_skips >= 5

    flaky_hosts.clear()                                # the host heals
    clock.run_until(clock.now() + 5.1)                 # cooldown passes
    for _ in range(10):
        settle_one()
        if board.breaker("host:0").state == CLOSED:
            break
    else:
        pytest.fail("half-open probe never revived host 0")
    assert board.summary()["probe_revivals"] >= 1
    assert 0 in agent.calls[mark + 5:]                 # back in rotation
    disp.close()


def test_retry_budget_bounds_attempt_amplification():
    clock = VirtualClock()
    cluster = SimCluster(clock, n_hosts=2, slots_per_host=2)
    agent = _StubAgent(clock, lambda host: (_ for _ in ()).throw(
        XlaRuntimeError("always down")))
    res = ResilienceConfig(retry_fraction=0.0, retry_floor=2.0,
                           backoff=BackoffPolicy(base_s=0.001, jitter=0.0))
    disp = Dispatcher(cluster, agent, hedging=False, max_retries=8,
                      clock=clock, resilience=res)
    futs = [disp.submit(None, [1], "sim") for _ in range(4)]
    clock.run_until_idle()
    disp.close()
    for fut in futs:
        with pytest.raises(XlaRuntimeError):
            fut.result(timeout=0)                      # settled exactly once
    assert disp.submitted == 4
    assert disp.retries == 2                           # the floor, no more
    assert disp.attempts == 4 + 2                      # amplification bounded
    assert disp.retries_denied == 4
    assert disp.retry_budget.denied == 4


def test_infeasible_retry_is_denied_not_scheduled():
    clock = VirtualClock()
    cluster = SimCluster(clock, n_hosts=2, slots_per_host=2)
    agent = _StubAgent(clock, lambda host: (_ for _ in ()).throw(
        XlaRuntimeError("crash")))
    res = ResilienceConfig(backoff=BackoffPolicy(base_s=1.0, factor=2.0,
                                                 cap_s=10.0, jitter=0.0))
    disp = Dispatcher(cluster, agent, hedging=False, max_retries=5,
                      clock=clock, resilience=res)
    fut = disp.submit(None, [1], "sim",
                      deadline=Deadline.after(0.5, clock=clock))
    clock.run_until_idle()
    disp.close()
    # the 1 s backoff cannot fit in the 0.5 s budget: the retry is refused
    # and the ORIGINAL error settles (no zombie attempt past the deadline)
    with pytest.raises(XlaRuntimeError):
        fut.result(timeout=0)
    assert disp.retries == 0
    assert disp.retries_denied == 1
    assert len(agent.calls) == 1


def test_expired_deadline_settles_without_dispatch():
    clock = VirtualClock()
    cluster = SimCluster(clock, n_hosts=1, slots_per_host=1)
    agent = _StubAgent(clock, lambda host: "ok")
    disp = Dispatcher(cluster, agent, hedging=False, clock=clock)
    fut = disp.submit(None, [1], "sim",
                      deadline=Deadline.after(0.0, clock=clock))
    clock.run_until_idle()
    disp.close()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=0)
    assert agent.calls == []                           # never reached a host


# ------------------------------------------------------ gateway front door


def test_gateway_sheds_via_admission_and_reports(gateway):
    gw, spec = gateway
    gw.admission = AdmissionController(ResilienceConfig(), capacity_slots=4)
    try:
        fut = gw.invoke_async(spec.name, deadline_s=0.0)
        with pytest.raises(AdmissionRejected):
            fut.result(timeout=1)
        assert gw.resilience_summary()["admission"]["shed"] == 1.0
        out = gw.invoke(spec.name, deadline_s=60.0)    # feasible: serves
        assert out is not None
        assert gw.resilience_summary()["admission"]["admitted"] == 1.0
    finally:
        gw.admission = None                            # shared session fixture


def test_gateway_deadline_propagates_to_dispatch(gateway):
    gw, spec = gateway
    # the sub-ms budget dies at the first checkpoint it reaches — the
    # dispatcher's pre-attempt gate or the agent's dispatch check; either
    # way the request settles DeadlineExceeded instead of booting anything
    with pytest.raises(DeadlineExceeded, match="deadline"):
        gw.invoke(spec.name, deadline_s=1e-6, timeout=60)
