"""Property tests for the paged-KV allocator (repro.core.paging).

Hypothesis-driven (real package or the deterministic stub): random
join/leave/grow/fork interleavings must never leak a page, never alias a page
across live unrelated chains, and exhaustion must admit-or-queue
deterministically — a failed reservation leaves the pool byte-for-byte
unchanged, never a half-built or corrupted chain.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.paging import NULL_PAGE, PageChain, PagePool


def _check_invariants(pool: PagePool, live):
    """The allocator's global invariants, checked after every mutation."""
    # conservation: every non-null page is either free or refcounted, never both
    assert pool.free_pages + pool.used_pages == pool.n_pages - 1
    # the null page is never handed out
    for chain in live:
        assert NULL_PAGE not in chain.pages
    # no aliasing: a page's refcount equals the number of live chains holding
    # it — no page is simultaneously free and owned, or owned by a chain that
    # never forked from its co-owner
    holders = {}
    for chain in live:
        for p in chain.pages:
            holders[p] = holders.get(p, 0) + 1
    for p, n in holders.items():
        assert pool.refcount(p) == n, (p, n, pool.refcount(p))
    assert pool.used_pages == len(holders)


@settings(max_examples=60)
@given(st.integers(4, 40), st.integers(1, 8),
       st.lists(st.integers(0, 10_000), min_size=1, max_size=80))
def test_random_join_leave_grow_never_leaks_or_aliases(n_pages, page_size, ops):
    pool = PagePool(n_pages, page_size)
    live = []
    for op in ops:
        kind = op % 4
        if kind == 0:                          # join: reserve a random worst case
            n_tokens = 1 + (op // 4) % (page_size * (n_pages - 1) + 3)
            chain = pool.alloc_chain(n_tokens)
            if chain is not None:
                assert chain.capacity >= n_tokens
                live.append(chain)
        elif kind == 1 and live:               # leave: release a random chain
            chain = live.pop((op // 4) % len(live))
            pool.release(chain)
            assert pool.release(chain) == 0    # double-release is a no-op
        elif kind == 2 and live:               # grow a random resident chain
            chain = live[(op // 4) % len(live)]
            before = list(chain.pages)
            ok = pool.extend(chain, chain.capacity + 1 + (op // 4) % page_size)
            if not ok:                         # all-or-nothing on exhaustion
                assert chain.pages == before
        elif kind == 3 and live:               # fork: share a prefix
            live.append(pool.fork(live[(op // 4) % len(live)]))
        _check_invariants(pool, live)
    for chain in live:
        pool.release(chain)
    assert pool.free_pages == pool.n_pages - 1
    assert pool.used_pages == 0


@settings(max_examples=40)
@given(st.integers(4, 24), st.integers(1, 8), st.integers(1, 2000))
def test_exhaustion_is_deterministic_and_corruption_free(n_pages, page_size,
                                                         n_tokens):
    """Admit-or-queue: when the pool can't cover a request, the answer is None
    and NOTHING changed — asking again with an unchanged pool gives the same
    answer, and resident chains keep their exact pages."""
    pool = PagePool(n_pages, page_size)
    resident = pool.alloc_chain(page_size)                 # one live chain
    assert resident is not None
    resident_pages = list(resident.pages)
    huge = (pool.n_pages + n_tokens) * page_size           # can never fit
    before = pool.stats()
    for _ in range(3):                                     # deterministic: same
        assert pool.alloc_chain(huge) is None              # answer every time
    after = pool.stats()
    before["alloc_failures"] = after["alloc_failures"]     # the only delta
    assert after == before
    assert resident.pages == resident_pages                # chain untouched
    _check_invariants(pool, [resident])
    # the pool still admits what does fit
    fit = pool.alloc_chain(page_size)
    assert fit is not None
    assert set(fit.pages).isdisjoint(resident_pages)


def test_fork_shares_pages_until_last_release():
    pool = PagePool(8, 4)
    a = pool.alloc_chain(10)                               # 3 pages
    b = pool.fork(a)
    assert b.pages == a.pages and b.pages is not a.pages
    for p in a.pages:
        assert pool.refcount(p) == 2
    assert pool.release(a) == 0                            # b still holds them
    assert pool.used_pages == 3
    assert pool.release(b) == 3                            # last referent frees
    assert pool.free_pages == 7


def test_extend_within_reservation_is_free():
    pool = PagePool(8, 4)
    chain = pool.alloc_chain(10)                           # capacity 12
    used = pool.used_pages
    assert pool.extend(chain, 12) is True
    assert pool.used_pages == used                         # no new pages
    assert pool.extend(chain, 13) is True                  # one page past
    assert pool.used_pages == used + 1


def test_released_chain_rejects_extend_and_fork():
    pool = PagePool(8, 4)
    chain = pool.alloc_chain(4)
    pool.release(chain)
    with pytest.raises(ValueError):
        pool.extend(chain, 8)
    with pytest.raises(ValueError):
        pool.fork(chain)


def test_table_row_pads_with_null_page():
    chain = PageChain([3, 1, 4], page_size=4)
    row = chain.table_row(6)
    assert row.tolist() == [3, 1, 4, NULL_PAGE, NULL_PAGE, NULL_PAGE]
    assert row.dtype.name == "int32"


def test_pool_rejects_degenerate_geometry():
    with pytest.raises(ValueError):
        PagePool(1, 4)                                     # only the null page
    with pytest.raises(ValueError):
        PagePool(4, 0)
