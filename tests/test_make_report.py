"""make_report._replace: idempotent marker substitution that never silently
drops table output (regression: a missing marker used to be a no-op, and
regex-active content like backslashes corrupted the substitution)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.make_report import _replace  # noqa: E402


def test_replace_fills_open_marker():
    md = "# Doc\n\n<!-- T -->\n"
    out = _replace(md, "T", "| a | b |")
    assert "<!-- T -->\n| a | b |\n<!-- /T -->" in out


def test_replace_is_idempotent():
    md = "# Doc\n\n<!-- T -->\n"
    once = _replace(md, "T", "| v1 |")
    twice = _replace(once, "T", "| v1 |")
    assert once == twice
    # and re-running with NEW content replaces, never nests or duplicates
    updated = _replace(once, "T", "| v2 |")
    assert "| v2 |" in updated and "| v1 |" not in updated
    assert updated.count("<!-- T -->") == 1
    assert updated.count("<!-- /T -->") == 1


def test_replace_missing_marker_appends_section_instead_of_dropping():
    """Regression: with no marker present the old code returned the input
    unchanged — the rendered table silently vanished."""
    md = "# Doc\n\nsome prose\n"
    out = _replace(md, "NEW_TABLE", "| x |", title="New table")
    assert "| x |" in out
    assert "## New table" in out
    assert out.startswith(md.rstrip())          # existing content untouched
    # and the appended section is itself idempotently replaceable now
    again = _replace(out, "NEW_TABLE", "| y |", title="New table")
    assert "| y |" in again and "| x |" not in again
    assert again.count("## New table") == 1


def test_replace_content_with_regex_escapes_survives():
    r"""Regression: re.sub with a string replacement interprets ``\g``/``\1``;
    table content containing backslashes (paths, regexes) must land verbatim."""
    md = "<!-- T -->\nold\n<!-- /T -->"
    tricky = r"C:\group \g<0> \1 \\ end"
    out = _replace(md, "T", tricky)
    assert tricky in out
    # idempotent on tricky content too
    assert _replace(out, "T", tricky) == out


def test_replace_only_touches_its_own_tag():
    md = ("<!-- A -->\na-old\n<!-- /A -->\n\n"
          "<!-- B -->\nb-old\n<!-- /B -->\n")
    out = _replace(md, "A", "a-new")
    assert "a-new" in out and "b-old" in out and "a-old" not in out
