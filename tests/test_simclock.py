"""Virtual clock semantics: event ordering, cancellation, horizon runs, and
the clock-injection plumbing in metrics (set_clock/use_clock)."""
import pytest

from repro.core import metrics
from repro.core.simclock import REAL, RealClock, VirtualClock


def test_events_fire_in_deadline_then_seq_order():
    clk = VirtualClock()
    fired = []
    clk.schedule(2.0, lambda: fired.append("late"))
    clk.schedule(1.0, lambda: fired.append("early-a"))
    clk.schedule(1.0, lambda: fired.append("early-b"))     # same deadline: FIFO
    assert clk.run_until_idle() == 3
    assert fired == ["early-a", "early-b", "late"]
    assert clk.now() == pytest.approx(2.0)


def test_now_equals_current_event_deadline():
    clk = VirtualClock()
    seen = []
    clk.schedule(0.5, lambda: seen.append(clk.now()))
    clk.schedule(1.5, lambda: seen.append(clk.now()))
    clk.run_until_idle()
    assert seen == [pytest.approx(0.5), pytest.approx(1.5)]


def test_cancelled_event_never_fires():
    clk = VirtualClock()
    fired = []
    ev = clk.schedule(1.0, lambda: fired.append("no"))
    clk.schedule(2.0, lambda: fired.append("yes"))
    ev.cancel()
    clk.run_until_idle()
    assert fired == ["yes"]


def test_callbacks_can_schedule_continuations():
    clk = VirtualClock()
    fired = []

    def chain(n):
        fired.append((clk.now(), n))
        if n < 3:
            clk.schedule(1.0, lambda: chain(n + 1))

    clk.schedule(1.0, lambda: chain(1))
    clk.run_until_idle()
    assert [n for _, n in fired] == [1, 2, 3]
    assert fired[-1][0] == pytest.approx(3.0)


def test_negative_delay_clamps_to_now():
    clk = VirtualClock(start=5.0)
    fired = []
    clk.schedule(-3.0, lambda: fired.append(clk.now()))
    clk.run_until_idle()
    assert fired == [pytest.approx(5.0)]       # never travels back in time


def test_run_until_respects_horizon_and_advances_now():
    clk = VirtualClock()
    fired = []
    clk.schedule(1.0, lambda: fired.append(1))
    clk.schedule(5.0, lambda: fired.append(5))
    assert clk.run_until(2.5) == 1
    assert fired == [1]
    assert clk.now() == pytest.approx(2.5)     # advances even with nothing due
    assert clk.pending() == 1
    clk.run_until_idle()
    assert fired == [1, 5]


def test_run_until_idle_max_events_backstop():
    clk = VirtualClock()

    def rearm():
        clk.schedule(1.0, rearm)               # self-perpetuating event

    clk.schedule(1.0, rearm)
    assert clk.run_until_idle(max_events=10) == 10     # bounded, no hang


def test_virtual_sleep_is_a_programming_error():
    with pytest.raises(RuntimeError):
        VirtualClock().sleep(0.1)


def test_real_clock_tracks_wall_time():
    clk = RealClock()
    assert not clk.virtual
    t0 = clk.now()
    clk.sleep(0.02)
    assert clk.now() - t0 >= 0.015


def test_metrics_use_clock_swaps_and_restores():
    assert metrics.get_clock() is REAL
    vclk = VirtualClock(start=42.0)
    with metrics.use_clock(vclk):
        assert metrics.get_clock() is vclk
        assert metrics.now() == pytest.approx(42.0)
    assert metrics.get_clock() is REAL
    assert metrics.set_clock(None) is REAL     # None -> REAL, returns previous
