"""DecodeScheduler behavior: the step-granular continuous-batching loop.

Token-exactness against the dense per-request decode path, slot backfill and
occupancy accounting, deterministic FIFO queueing under page exhaustion,
cool-to-zero with residency accounting, EOS/deadline retirement, and the
error path that settles every future without killing the loop.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FunctionSpec, Gateway
from repro.core.decode import DecodeConfig, DecodeScheduler
from repro.core.paging import PagePool
from repro.core.resilience import DeadlineExceeded


@pytest.fixture(scope="module")
def dgw():
    """Cold-mode platform with the continuous-batching decode tier enabled."""
    gw = Gateway(n_hosts=2, slots_per_host=2, mode="cold", hedging=False,
                 decode=DecodeConfig(slots=3, page_size=8, cool_after_s=0.15))
    spec = FunctionSpec(arch="llama3.2-3b", batch_size=1, prompt_len=8,
                        decode_steps=12)
    gw.deploy(spec)
    yield gw, spec
    gw.shutdown()


def _dense_greedy(dep, tokens, budget):
    """The request-granular oracle: prefill + per-token greedy decode on a
    contiguous cache, exactly the math of the fused serve program."""
    model = dep.model
    params = model.init(jax.random.PRNGKey(dep.spec.seed))
    capacity = dep.spec.prompt_len + dep.spec.decode_steps
    lg, cache = model.prefill(params, {"tokens": jnp.asarray(tokens)},
                              capacity=capacity)
    toks = []
    for _ in range(budget):
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
        lg, cache = model.decode(params, cache, tok)
    return toks


BUDGETS = [12, 3, 7, 12, 1, 5]


def test_mixed_budgets_token_exact(dgw):
    """Six requests with wildly different budgets share the step loop and each
    gets exactly its own greedy continuation — bit-identical to running it
    alone on the dense path, and exactly ``max_new`` tokens, never padded to a
    bucket's fused budget."""
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    futs = [gw.invoke_decode_async(spec.name,
                                   tokens=dep.example_tokens(seed=i)[:1],
                                   max_new=b, label=f"req{i}")
            for i, b in enumerate(BUDGETS)]
    outs = [f.result(300) for f in futs]
    for i, (b, out) in enumerate(zip(BUDGETS, outs)):
        assert out.shape == (b,)
        assert out.tolist() == _dense_greedy(
            dep, dep.example_tokens(seed=i)[:1], b)
    s = gw.decode_summary(spec.name)
    assert s["requests"] >= len(BUDGETS)
    assert s["admits"] >= len(BUDGETS)
    assert s["tokens_generated"] >= sum(BUDGETS)
    # step-granular: total steps is bounded by the per-request sum, and the
    # early-finishing rows never hold their slot for even one extra step
    assert s["steps"] < sum(BUDGETS)
    assert s["occupancy"] > 0.25
    assert s["page_alloc_failures"] == 0


def test_timelines_carry_ttfr(dgw):
    gw, spec = dgw
    gw.invoke_decode(spec.name, max_new=3, label="ttfr-probe")
    tls = gw.recorder.timelines("ttfr-probe")
    assert tls
    tl = tls[-1]
    assert tl.t_ttfr is not None
    # first token lands at admit — before the last step retires the request
    assert tl.t_exec_begin <= tl.t_ttfr <= tl.t_done


def test_eos_retires_early(dgw):
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    toks = _dense_greedy(dep, dep.example_tokens(seed=99)[:1], 6)
    eos = toks[2]
    want = toks[:toks.index(eos) + 1]
    sched = DecodeScheduler(
        dep, gw.cluster, gw.recorder,
        DecodeConfig(slots=2, page_size=8, cool_after_s=0.1, eos_token=eos))
    try:
        out = sched.submit(dep.example_tokens(seed=99)[:1]).result(300)
    finally:
        sched.close()
    assert out.tolist() == want
    assert sched.pool.used_pages == 0


def test_cool_to_zero_and_reboot(dgw):
    gw, spec = dgw
    dec = gw.decoders[spec.name]
    res0 = gw.residency.summary()["total_GBs"]
    gw.invoke_decode(spec.name, max_new=2)
    boots0, cools0 = dec.boots, dec.cooldowns
    deadline = time.time() + 10
    # wait on the counter, not _ex: _cool() clears _ex before it finishes
    # accounting, so _ex going None only means the cooldown has BEGUN
    while dec.cooldowns < cools0 + 1 and time.time() < deadline:
        time.sleep(0.05)
    assert dec.cooldowns >= cools0 + 1
    assert dec._ex is None, "decode executor must cool to ZERO after quiet"
    # the cooled executor's residency landed in the platform tracker
    assert gw.residency.summary()["total_GBs"] > res0
    # the next burst pays a fresh boot — no warm remnant survived
    out = gw.invoke_decode(spec.name, max_new=2)
    assert out.shape == (2,)
    assert dec.boots == boots0 + 1


def test_page_exhaustion_queues_fifo_without_corruption(dgw):
    """Shrink the accounting pool so only ONE request's reservation fits: the
    queue head waits (admit-or-queue), later requests never jump it, and every
    serialized request still decodes token-exactly."""
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    sched = DecodeScheduler(dep, gw.cluster, gw.recorder,
                            DecodeConfig(slots=3, page_size=8,
                                         cool_after_s=0.1))
    sched.pool = PagePool(4, 8)      # 3 allocatable pages = one 20-token chain
    order = []
    try:
        futs = []
        for i in range(3):
            fut = sched.submit(dep.example_tokens(seed=i)[:1], max_new=12)
            fut.add_done_callback(lambda _f, i=i: order.append(i))
            futs.append(fut)
        outs = [f.result(300) for f in futs]
    finally:
        sched.close()
    for i, out in enumerate(outs):
        assert out.tolist() == _dense_greedy(
            dep, dep.example_tokens(seed=i)[:1], 12)
    assert order == [0, 1, 2]                     # FIFO, no starvation
    assert sched.admit_waits >= 1                 # head actually waited
    assert sched.pool.alloc_failures >= 1
    assert sched.steps == sched.step_rows         # one resident at a time
    assert sched.pool.used_pages == 0


def test_submit_rejects_malformed_and_oversized(dgw):
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    dec = gw.decoders[spec.name]
    bad = dec.submit(np.zeros((2, spec.prompt_len), np.int32))
    with pytest.raises(ValueError, match="prompt must be"):
        bad.result(1)
    # a worst case no reservation can cover is rejected synchronously, not
    # left to spin at the queue head forever
    big = DecodeScheduler(dep, gw.cluster, gw.recorder,
                          DecodeConfig(slots=3, page_size=8, max_new=1000))
    try:
        with pytest.raises(ValueError, match="pages"):
            big.submit(dep.example_tokens()[:1]).result(1)
    finally:
        big.close()


def test_expired_deadline_settles_the_future(dgw):
    gw, spec = dgw
    fut = gw.invoke_decode_async(spec.name, max_new=3, deadline_s=1e-6)
    with pytest.raises(DeadlineExceeded):
        fut.result(300)
    # the loop is still healthy afterwards
    assert gw.invoke_decode(spec.name, max_new=1).shape == (1,)


def test_step_failure_settles_futures_and_loop_survives(dgw):
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    sched = DecodeScheduler(dep, gw.cluster, gw.recorder,
                            DecodeConfig(slots=2, page_size=8,
                                         cool_after_s=0.1))
    real = sched.bundle

    def boom(*_a, **_k):
        raise RuntimeError("injected step failure")

    sched.bundle = dataclasses.replace(real, step=boom)
    try:
        fut = sched.submit(dep.example_tokens(seed=7)[:1], max_new=4)
        with pytest.raises(RuntimeError, match="injected"):
            fut.result(300)
        assert sched.pool.used_pages == 0         # pages released on failure
        sched.bundle = real                       # next burst: fresh boot
        out = sched.submit(dep.example_tokens(seed=7)[:1], max_new=4).result(300)
    finally:
        sched.close()
    assert out.tolist() == _dense_greedy(dep, dep.example_tokens(seed=7)[:1], 4)


def test_close_during_inflight_admit_settles_the_future(dgw):
    """A request mid-admit is in neither ``_queue`` nor ``_slots`` — drain()
    (and so close()) must still see it via the in-flight count and wait, or
    close() cools the executor under the prefill and the future never
    settles."""
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    sched = DecodeScheduler(dep, gw.cluster, gw.recorder,
                            DecodeConfig(slots=2, page_size=8,
                                         cool_after_s=0.1))
    real = sched.bundle
    started = threading.Event()

    def slow_admit(*a, **k):
        started.set()
        time.sleep(0.3)                   # hold the request in the admit gap
        return real.admit(*a, **k)

    sched.bundle = dataclasses.replace(real, admit=slow_admit)
    # budget > 1 extra step so retirement spans several loop iterations —
    # close() must wait through the admit AND the remaining steps
    fut = sched.submit(dep.example_tokens(seed=3)[:1], max_new=6)
    assert started.wait(60)
    sched.close()                         # races the in-flight admit
    out = fut.result(1)                   # settled BEFORE close() returned
    assert out.tolist() == _dense_greedy(dep, dep.example_tokens(seed=3)[:1], 6)
    assert sched.pool.used_pages == 0
    assert sched._ex is None


def test_submit_rejects_out_of_range_max_new(dgw):
    """max_new is validated, never clamped: over-budget asks fail loudly
    instead of returning silently truncated output, and 0 (admit always emits
    one token) is an error, not the full default budget."""
    gw, spec = dgw
    dec = gw.decoders[spec.name]
    for bad in (0, -1, spec.decode_steps + 1):
        with pytest.raises(ValueError, match="max_new must be in"):
            dec.submit(gw.deployments[spec.name].example_tokens()[:1],
                       max_new=bad).result(1)
    # None still means the full deploy budget
    out = gw.invoke_decode(spec.name)
    assert out.shape == (spec.decode_steps,)


def test_redeploy_closes_the_old_decoder():
    """Re-deploying a name must drain + cool the old scheduler, not leak its
    loop thread and executor outside the residency accounting."""
    gw = Gateway(n_hosts=1, slots_per_host=2, mode="cold", hedging=False,
                 decode=DecodeConfig(slots=2, page_size=8, cool_after_s=0.1))
    try:
        spec = FunctionSpec(arch="llama3.2-3b", batch_size=1, prompt_len=8,
                            decode_steps=4)
        gw.deploy(spec)
        old = gw.decoders[spec.name]
        gw.invoke_decode(spec.name, max_new=2)
        gw.deploy(spec)
        new = gw.decoders[spec.name]
        assert new is not old
        assert not old._running
        assert old._ex is None
        assert not old._thread.is_alive()
        assert gw.invoke_decode(spec.name, max_new=2).shape == (2,)
    finally:
        gw.shutdown()


def test_boot_failure_after_start_exits_the_executor(dgw, monkeypatch):
    """If post-start setup (page-pool init) fails, the started executor must
    be exited with its residency accounted — not silently leaked off
    ``self._ex``."""
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    exited = []
    sched = DecodeScheduler(dep, gw.cluster, gw.recorder,
                            DecodeConfig(slots=2, page_size=8,
                                         cool_after_s=0.1),
                            on_exit=exited.append)
    real_init = type(dep.model).init_page_pool
    fail = {"on": True}

    def flaky_init(self, *a, **k):
        if fail["on"]:
            raise RuntimeError("injected pool-init failure")
        return real_init(self, *a, **k)

    monkeypatch.setattr(type(dep.model), "init_page_pool", flaky_init)
    try:
        fut = sched.submit(dep.example_tokens(seed=5)[:1], max_new=2)
        with pytest.raises(RuntimeError, match="injected pool-init"):
            fut.result(300)
        assert len(exited) == 1               # started executor was exited...
        assert sched._ex is None              # ...and never published
        assert sched.pool.used_pages == 0
        fail["on"] = False
        # the loop survived the failed boot AND the per-request error path
        out = sched.submit(dep.example_tokens(seed=5)[:1], max_new=2).result(300)
        assert out.tolist() == _dense_greedy(
            dep, dep.example_tokens(seed=5)[:1], 2)
    finally:
        sched.close()


def test_decode_bundle_is_a_deploy_time_artifact(dgw):
    gw, spec = dgw
    dep = gw.deployments[spec.name]
    b1 = dep.ensure_decode(3, 8)
    b2 = dep.ensure_decode(3, 8)
    assert b1 is b2                               # compiled once, ever
    assert b1.aot_verified                        # serialized + reloaded
    assert b1.n_pages == 1 + b1.slots * b1.max_pages
