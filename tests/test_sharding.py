"""Sharding rules: shape-aware axis assignment + property tests (hypothesis)."""
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import Rules, constrain, make_rules, preset_names


def fake_rules(sizes, preset_mapping):
    return Rules("test", preset_mapping, tuple(sizes), dict(sizes))


MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def test_divisible_axis_assigned():
    r = fake_rules(MESH_SIZES, {"vocab": "model"})
    assert r.spec(("vocab",), (152064,)) == P("model")


def test_non_divisible_axis_dropped():
    r = fake_rules(MESH_SIZES, {"kv_heads": "model"})
    assert r.spec(("kv_heads",), (2,)) == P(None)


def test_fallthrough_to_head_dim():
    r = fake_rules(MESH_SIZES, {"kv_heads": "model", "head_dim": "model"})
    # kv_heads=2 can't take model=16 -> head_dim picks it up
    assert r.spec(("kv_heads", "head_dim"), (2, 128)) == P(None, "model")
    # kv_heads=16 takes it; head_dim then must not reuse the axis
    assert r.spec(("kv_heads", "head_dim"), (16, 128)) == P("model", None)


def test_tuple_target_prefix():
    r = fake_rules(MESH_SIZES, {"batch": ("pod", "data")})
    assert r.spec(("batch",), (256,)) == P(("pod", "data"))
    assert r.spec(("batch",), (2,)) == P("pod")        # only pod=2 divides
    assert r.spec(("batch",), (1,)) == P(None)


def test_pod_dropped_on_single_pod_mesh():
    r = fake_rules({"data": 16, "model": 16}, {"batch": ("pod", "data")})
    assert r.spec(("batch",), (256,)) == P("data")


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "embed") is x


def test_all_presets_resolve():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    for preset in preset_names():
        r = make_rules(preset, mesh)
        assert r.spec(("batch", "embed"), (8, 128)) is not None


@settings(max_examples=200, deadline=None)
@given(
    dim=st.integers(min_value=1, max_value=4096),
    axes=st.sampled_from(["batch", "embed", "ffn", "vocab", "kv_heads",
                          "experts", "heads"]),
)
def test_property_spec_always_divides(dim, axes):
    """Whatever the shape, the assigned mesh-axis product must divide the dim."""
    mapping = {
        "batch": ("pod", "data"), "embed": "data", "ffn": "model",
        "vocab": "model", "kv_heads": "model", "experts": "model",
        "heads": "model",
    }
    r = fake_rules(MESH_SIZES, mapping)
    spec = r.spec((axes,), (dim,))
    part = spec[0]
    if part is None:
        return
    names = (part,) if isinstance(part, str) else part
    prod = 1
    for n in names:
        prod *= MESH_SIZES[n]
    assert dim % prod == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=64), min_size=1, max_size=4))
def test_property_no_mesh_axis_used_twice(dims):
    mapping = {"a": "model", "b": "model", "c": ("data", "model"), "d": "data"}
    r = fake_rules(MESH_SIZES, mapping)
    axes = ["a", "b", "c", "d"][: len(dims)]
    spec = r.spec(tuple(axes), tuple(d * 16 * 32 for d in dims))  # all divisible
    seen = []
    for part in spec:
        if part is None:
            continue
        seen.extend([part] if isinstance(part, str) else list(part))
    assert len(seen) == len(set(seen)), spec
