"""Trace generator guarantees the forecast bench stands on.

Three properties, matching benchmarks/traces.py's contract:

* determinism — same (populations, duration, seed) reproduces the same
  trace byte-for-byte, per-population streams are independent (adding a
  population never perturbs another's arrivals);
* statistics — diurnal arrivals actually carry the configured period (the
  peak phase bucket sees ~(1+amplitude)/(1-amplitude) times the trough's
  arrivals), MMPP burst lengths match the configured dwell means within
  tolerance, one-shots fire exactly once each;
* scheduling — ``schedule_arrivals`` delivers every arrival at its trace
  time on a virtual clock with no real sleeping and only ONE pending clock
  event at a time (constant footprint for million-event traces).
"""
import sys
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.traces import (  # noqa: E402
    BurstyPop,
    DiurnalPop,
    OneShotPop,
    bucket_rates,
    default_populations,
    generate_trace,
    schedule_arrivals,
    training_windows,
)
from repro.core.simclock import VirtualClock  # noqa: E402


# ------------------------------------------------------------- determinism

def test_same_seed_reproduces_trace_exactly():
    pops = default_populations()
    a = generate_trace(pops, 120.0, seed=7)
    b = generate_trace(pops, 120.0, seed=7)
    assert a == b
    assert len(a) > 100


def test_different_seeds_differ():
    pops = default_populations()
    assert generate_trace(pops, 60.0, 1) != generate_trace(pops, 60.0, 2)


def test_population_streams_are_independent():
    """Adding a population must not perturb another's arrivals (each derives
    its own RNG from (seed, name))."""
    solo = DiurnalPop("d", base_rate=5.0)
    alone = solo.generate(60.0, seed=3)
    mixed = generate_trace([solo, BurstyPop("b"), OneShotPop("o")], 60.0,
                           seed=3)
    assert [a for a in mixed if a[1] == "d"] == alone


# -------------------------------------------------------------- statistics

def test_diurnal_arrivals_carry_the_configured_period():
    """Fold arrivals by phase: peak-quarter mass over trough-quarter mass
    approaches (1 + amplitude) / (1 - amplitude)."""
    pop = DiurnalPop("d", base_rate=30.0, amplitude=0.8, period_s=60.0)
    arrivals = pop.generate(600.0, seed=0)          # ~10 periods
    phases = np.asarray([t % 60.0 for t, _ in arrivals])
    # peak at t=15 (sin max), trough at t=45: quarter-period windows
    peak = np.sum((phases >= 7.5) & (phases < 22.5))
    trough = np.sum((phases >= 37.5) & (phases < 52.5))
    expected = (1 + 0.8) / (1 - 0.8)                # 9x, minus window blur
    assert peak / max(trough, 1) > expected * 0.5
    # mean rate within 15% of base_rate
    assert abs(len(arrivals) / 600.0 - 30.0) < 0.15 * 30.0


def test_bursty_on_off_structure():
    """MMPP arrivals cluster: gaps >> mean_on_s are OFF dwells and their mean
    approaches mean_off_s; total mass matches duty-cycle x rate_on."""
    pop = BurstyPop("b", rate_on=40.0, mean_on_s=3.0, mean_off_s=25.0)
    arrivals = pop.generate(2000.0, seed=1)
    times = np.asarray([t for t, _ in arrivals])
    gaps = np.diff(times)
    off_gaps = gaps[gaps > 3.0]                     # longer than an ON dwell
    assert off_gaps.size >= 10
    assert 10.0 < off_gaps.mean() < 50.0            # ~mean_off_s
    duty = 3.0 / (3.0 + 25.0)
    expect = 40.0 * duty * 2000.0
    assert abs(times.size - expect) < 0.35 * expect


def test_oneshots_fire_exactly_once_each():
    pop = OneShotPop("cron", n_functions=9)
    arrivals = pop.generate(100.0, seed=5)
    names = [fn for _, fn in arrivals]
    assert len(names) == 9 and len(set(names)) == 9
    assert all(0.0 <= t < 100.0 for t, _ in arrivals)


def test_bucket_rates_conserves_mass():
    pops = default_populations()
    trace = generate_trace(pops, 90.0, seed=2)
    rates = bucket_rates(trace, 90.0, bucket_s=1.0)
    total = sum(float(r.sum()) for r in rates.values())
    assert total == len(trace)                      # bucket_s=1: rate == count


def test_training_windows_shapes_and_targets():
    X, y = training_windows(default_populations(), seed=4, duration_s=200.0,
                            window=32, horizon_s=2.0)
    assert X.ndim == 2 and X.shape[1] == 32
    assert y.shape == (X.shape[0],)
    assert np.all(X >= 0.0) and np.all(y >= 0.0)


# -------------------------------------------------------------- scheduling

def test_schedule_arrivals_is_virtual_and_incremental():
    """Every arrival lands at its trace time, the walk never sleeps for
    real, and at most one arrival event is pending at any instant."""
    clock = VirtualClock()
    trace = generate_trace(default_populations(), 30.0, seed=6)
    seen = []
    schedule_arrivals(clock, trace, lambda fn: seen.append((clock.now(), fn)))
    assert clock.pending() <= 1                     # incremental chaining
    wall = time.perf_counter()
    clock.run_until_idle()
    wall = time.perf_counter() - wall
    assert wall < 5.0                               # no real 30 s of sleeping
    assert len(seen) == len(trace)
    for (t_seen, fn_seen), (t_trace, fn_trace) in zip(seen, trace):
        assert fn_seen == fn_trace
        assert abs(t_seen - t_trace) < 1e-6
