"""Dispatcher unit tests on a fake cluster: retry, transient classification,
and hedged straggler mitigation (exactly one backup, bail-early watcher)."""
import threading
import time

import pytest

from repro.core.cluster import Cluster, HostFailure
from repro.core.dispatcher import Dispatcher, _LatencyModel, _is_transient
from repro.core.metrics import now


class FakeAgent:
    """Scriptable stand-in for repro.core.agent.Agent: ``behavior(attempt_no)``
    either returns a value, raises, or sleeps then returns."""

    def __init__(self, behavior):
        self.behavior = behavior
        self.calls = []
        self._lock = threading.Lock()

    def handle(self, host, dep, tokens, driver_name, tl, label):
        with self._lock:
            n = len(self.calls)
            self.calls.append(host.host_id)
        tl.t_dispatch = tl.t_dispatch or now()
        out = self.behavior(n)
        tl.t_done = now()
        return out


def make_dispatcher(behavior, *, n_hosts=2, **kw):
    cluster = Cluster(n_hosts=n_hosts, slots_per_host=2)
    agent = FakeAgent(behavior)
    return Dispatcher(cluster, agent, **kw), cluster, agent


# -------------------------------------------------------------------- retry

def test_retry_on_host_failure_then_success():
    def behavior(n):
        if n == 0:
            raise HostFailure("injected")
        return "ok"

    disp, cluster, agent = make_dispatcher(behavior, hedging=False)
    try:
        assert disp.submit(None, [1, 2], "proc").result(timeout=10) == "ok"
        assert disp.retries == 1
        assert len(agent.calls) == 2
        assert agent.calls[0] != agent.calls[1]      # re-dispatched elsewhere
    finally:
        cluster.shutdown()


def test_retry_exhausts_max_retries():
    def behavior(n):
        raise HostFailure(f"attempt {n}")

    disp, cluster, agent = make_dispatcher(behavior, max_retries=3, hedging=False)
    try:
        fut = disp.submit(None, [1], "proc")
        with pytest.raises(HostFailure):
            fut.result(timeout=10)
        # initial attempt + max_retries re-dispatches, then gives up
        assert len(agent.calls) == 4
        assert disp.retries == 3
    finally:
        cluster.shutdown()


def test_non_retryable_error_propagates_immediately():
    def behavior(n):
        raise ValueError("bad input")

    disp, cluster, agent = make_dispatcher(behavior, hedging=False)
    try:
        fut = disp.submit(None, [1], "proc")
        with pytest.raises(ValueError):
            fut.result(timeout=10)
        assert len(agent.calls) == 1
        assert disp.retries == 0
    finally:
        cluster.shutdown()


# ---------------------------------------------------------------- transient

def test_is_transient_classification():
    class JaxRuntimeError(Exception):
        pass

    class XlaRuntimeError(Exception):
        pass

    assert _is_transient(JaxRuntimeError("device lost"))
    assert _is_transient(XlaRuntimeError("dead"))
    assert _is_transient(RuntimeError("program not found in cache"))
    assert not _is_transient(RuntimeError("shape mismatch"))
    assert not _is_transient(ValueError("not found"))    # RuntimeError only


# ------------------------------------------------------------------ hedging

def _seed_p95(disp, key, value=0.02, n=10):
    assert n >= 8                                 # _LatencyModel needs >= 8
    for _ in range(n):
        disp.latency.observe(key, value)
    assert disp.latency.p95(key) == pytest.approx(value)


def test_latency_model_needs_8_samples():
    lm = _LatencyModel()
    for i in range(7):
        lm.observe("k", 0.01)
        assert lm.p95("k") is None
    lm.observe("k", 0.01)
    assert lm.p95("k") == pytest.approx(0.01)


def test_hedge_launches_exactly_one_backup():
    first_started = threading.Event()

    def behavior(n):
        if n == 0:                                # straggler: way past 3 x p95
            first_started.set()
            time.sleep(1.0)
            return "slow"
        return "fast"

    disp, cluster, agent = make_dispatcher(behavior, hedge_factor=3.0)
    _seed_p95(disp, "noop:proc")
    try:
        t0 = time.perf_counter()
        fut = disp.submit(None, [1], "proc")
        assert fut.result(timeout=10) == "fast"   # backup wins the race
        assert time.perf_counter() - t0 < 1.0     # didn't wait for the straggler
        assert first_started.is_set()
        assert disp.hedges_launched == 1
        time.sleep(0.2)                           # no second hedge appears
        assert disp.hedges_launched == 1
        assert len(agent.calls) == 2
    finally:
        cluster.shutdown()


def test_no_hedge_when_attempt_is_fast():
    disp, cluster, agent = make_dispatcher(lambda n: "ok", hedge_factor=3.0)
    _seed_p95(disp, "noop:proc")
    try:
        assert disp.submit(None, [1], "proc").result(timeout=10) == "ok"
        time.sleep(0.3)                           # longer than 3 x p95
        assert disp.hedges_launched == 0
        assert len(agent.calls) == 1
    finally:
        cluster.shutdown()


def test_hedge_watcher_bails_once_result_is_done():
    """The watcher thread must exit as soon as the request settles, not block
    its daemon thread for the full hedge deadline (thread-leak regression)."""
    def behavior(n):
        return "ok"

    def watchers():
        return [t for t in threading.enumerate() if "hedge_watch" in t.name]

    disp, cluster, agent = make_dispatcher(behavior, hedge_factor=3.0)
    _seed_p95(disp, "noop:proc", value=30.0)      # deadline would be 90 s
    try:
        assert disp.submit(None, [1], "proc").result(timeout=10) == "ok"
        deadline = time.time() + 5.0
        while watchers() and time.time() < deadline:
            time.sleep(0.01)
        assert not watchers()                      # exited well before 90 s
        assert disp.hedges_launched == 0
    finally:
        cluster.shutdown()
