import os
import sys

os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")   # silence XLA AOT-loader notices
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                  # the container image doesn't ship hypothesis;
    import hypothesis  # noqa: F401   # fall back to the deterministic stub
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def reduced_f32(arch: str):
    """Reduced config in float32 (tight numeric tests)."""
    from repro.configs import get_config
    return dataclasses.replace(get_config(arch).reduced(), dtype="float32")


@pytest.fixture(scope="session")
def gateway():
    """One shared cold-mode platform with a tiny deployed function."""
    from repro.core import FunctionSpec, Gateway
    gw = Gateway(n_hosts=2, slots_per_host=2, mode="cold", hedging=False)
    spec = FunctionSpec(arch="llama3.2-3b", batch_size=2, prompt_len=16,
                        decode_steps=2)
    gw.deploy(spec)
    yield gw, spec
    gw.shutdown()
