"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm import mlstm
from repro.kernels.selective_scan import selective_scan

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------------- flash attention

FLASH_CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, q_offset
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 64, 64, 8, 8, 32, True, 0),
    (2, 64, 192, 4, 1, 128, True, 128),      # GQA=4, prefill continuation
    (1, 128, 128, 2, 2, 64, False, 0),       # bidirectional (whisper encoder)
    (1, 96, 96, 6, 3, 64, True, 0),          # non-power-of-two seq (padding)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, Sq, Skv, Hq, Hkv, D, causal, qoff = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, q_offset=qoff, interpret=True)
    exp = ref.flash_attention(q, k, v, causal=causal, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol(dtype))


def test_flash_ref_vs_naive_oracle():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 37, 8, 16))
    k = jax.random.normal(ks[1], (2, 37, 2, 16))
    v = jax.random.normal(ks[2], (2, 37, 2, 16))
    a = ref.flash_attention(q, k, v, block_q=16, block_kv=8)
    b = ref.naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_attention_grads_match_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))

    def loss_pal(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_, interpret=True) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.sum(ref.flash_attention(q_, k_, v_) ** 2)

    g1 = jax.grad(loss_pal, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ------------------------------------------------------------ decode attention

DECODE_CASES = [
    (2, 256, 8, 2, 64), (3, 100, 4, 4, 32), (1, 512, 16, 8, 128), (2, 64, 2, 1, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(case, dtype):
    B, S, Hq, Hkv, D = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    length = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, kc, vc, length, block_kv=64, interpret=True)
    exp = ref.decode_attention(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol(dtype))


def test_decode_ref_vs_naive_oracle():
    ks = jax.random.split(KEY, 3)
    B, S, Hq, Hkv, D = 2, 50, 4, 2, 16
    q = jax.random.normal(ks[0], (B, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    got = ref.decode_attention(q, kc, vc, jnp.int32(S), block_kv=16)
    exp = ref.naive_attention(q[:, None], kc, vc, causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


# -------------------------------------------------------------- selective scan

SCAN_CASES = [(2, 96, 64, 16), (1, 33, 128, 8), (2, 128, 256, 4)]


@pytest.mark.parametrize("case", SCAN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_vs_ref(case, dtype):
    B, S, Di, Ds = case
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (B, S, Di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))).astype(dtype)
    a_log = jax.random.normal(ks[2], (Di, Ds)) * 0.5
    b = jax.random.normal(ks[3], (B, S, Ds), dtype)
    c = jax.random.normal(ks[4], (B, S, Ds), dtype)
    d_skip = jax.random.normal(ks[5], (Di,))
    h0 = jax.random.normal(ks[6], (B, Di, Ds))
    y1, h1 = ref.selective_scan(x, dt, a_log, b, c, d_skip, h0=h0, block=8)
    y2, h2 = selective_scan(x, dt, a_log, b, c, d_skip, h0=h0,
                            block_di=min(Di, 64), chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2, np.float32),
                               atol=10 * tol(dtype))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=10 * tol(dtype))


def test_selective_scan_ref_vs_step_oracle():
    B, S, Di, Ds = 2, 19, 8, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    a_log = jax.random.normal(ks[2], (Di, Ds)) * 0.5
    b = jax.random.normal(ks[3], (B, S, Ds))
    c = jax.random.normal(ks[4], (B, S, Ds))
    d_skip = jax.random.normal(ks[5], (Di,))
    y, hf = ref.selective_scan(x, dt, a_log, b, c, d_skip, block=4)
    h = jnp.zeros((B, Di, Ds))
    for t in range(S):
        yt, h = ref.mamba_step(x[:, t], dt[:, t], a_log, b[:, t], c[:, t], d_skip, h)
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(yt), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h), atol=1e-4)


# ----------------------------------------------------------------------- mlstm

MLSTM_CASES = [(2, 96, 2, 32, 64), (1, 50, 4, 16, 16), (2, 64, 1, 64, 128)]


@pytest.mark.parametrize("case", MLSTM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_vs_ref(case, dtype):
    B, S, H, Dk, Dv = case
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, Dk), dtype)
    k = jax.random.normal(ks[1], (B, S, H, Dk), dtype)
    v = jax.random.normal(ks[2], (B, S, H, Dv), dtype)
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h1, st1 = ref.mlstm_chunked(q, k, v, ig, fg, block=16)
    h2, st2 = mlstm(q, k, v, ig, fg, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(h1, np.float32), np.asarray(h2, np.float32),
                               atol=10 * tol(dtype))
    for a, b in zip(st1, st2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=10 * tol(dtype))


def test_mlstm_ref_vs_recurrent_oracle():
    B, S, H, Dk, Dv = 2, 29, 2, 8, 12
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h1, st1 = ref.mlstm_chunked(q, k, v, ig, fg, block=8)
    h2, st2 = ref.mlstm_recurrent(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    for a, b in zip(st1, st2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_mlstm_state_continuation():
    """Chunked-with-carried-state == one long chunked pass."""
    B, S, H, Dk, Dv = 1, 64, 2, 16, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h_full, _ = ref.mlstm_chunked(q, k, v, ig, fg, block=16)
    half = S // 2
    h1, st = ref.mlstm_chunked(q[:, :half], k[:, :half], v[:, :half],
                               ig[:, :half], fg[:, :half], block=16)
    h2, _ = ref.mlstm_chunked(q[:, half:], k[:, half:], v[:, half:],
                              ig[:, half:], fg[:, half:], state=st, block=16)
    got = jnp.concatenate([h1, h2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(h_full), atol=1e-4)
