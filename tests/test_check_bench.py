"""tools/check_bench.py: schema contract + regression gate, and the repo's
own committed BENCH_*.json artifacts must satisfy it (the tier-1 side of the
CI step — a malformed or regressed artifact fails before it merges)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from tools.check_bench import (  # noqa: E402
    check,
    compare_headline,
    validate_schema,
)


def _valid(**over):
    data = {
        "schema_version": 2,
        "bench": "demo",
        "run_id": "demo-16x4-seed0",
        "seed": 0,
        "headline": {
            "p99_ms": {"value": 50.0, "better": "lower", "rel_tol": 0.25},
            "hit_rate": {"value": 0.9, "better": "higher", "rel_tol": 0.10},
        },
    }
    data.update(over)
    return data


# ------------------------------------------------------------------ schema

def test_valid_artifact_passes():
    assert validate_schema(_valid(), "x.json") == []


@pytest.mark.parametrize("mutate", [
    {"schema_version": 1},
    {"bench": ""},
    {"run_id": None},
    {"seed": "0"},
    {"headline": {}},
    {"headline": {"m": {"value": float("nan"), "better": "lower",
                        "rel_tol": 0.1}}},
    {"headline": {"m": {"value": 1.0, "better": "sideways", "rel_tol": 0.1}}},
    {"headline": {"m": {"value": 1.0, "better": "lower", "rel_tol": 2.0}}},
])
def test_schema_violations_are_reported(mutate):
    assert validate_schema(_valid(**mutate), "x.json")


# -------------------------------------------------------------- regression

def test_within_tolerance_is_ok():
    cur = _valid()
    cur["headline"]["p99_ms"]["value"] = 60.0       # +20% < 25% tol
    regressions, notes = compare_headline(cur, _valid(), "x.json")
    assert regressions == [] and notes


def test_lower_is_better_regression_fails():
    cur = _valid()
    cur["headline"]["p99_ms"]["value"] = 70.0       # +40% > 25% tol
    regressions, _ = compare_headline(cur, _valid(), "x.json")
    assert any("p99_ms" in r for r in regressions)


def test_higher_is_better_regression_fails():
    cur = _valid()
    cur["headline"]["hit_rate"]["value"] = 0.5      # -44% > 10% tol
    regressions, _ = compare_headline(cur, _valid(), "x.json")
    assert any("hit_rate" in r for r in regressions)


def test_dropped_headline_metric_fails():
    cur = _valid()
    del cur["headline"]["hit_rate"]
    regressions, _ = compare_headline(cur, _valid(), "x.json")
    assert any("disappeared" in r for r in regressions)


def test_run_id_change_skips_comparison():
    cur = _valid(run_id="demo-32x8-seed0")
    cur["headline"]["p99_ms"]["value"] = 500.0      # would regress hard
    regressions, notes = compare_headline(cur, _valid(), "x.json")
    assert regressions == []
    assert any("no comparison" in n for n in notes)


def test_v1_baseline_skips_comparison():
    regressions, notes = compare_headline(
        _valid(), _valid(schema_version=1), "x.json")
    assert regressions == []
    assert any("no comparison" in n for n in notes)


def test_baseline_rel_tol_is_the_bar():
    """The committed baseline's tolerance governs — a PR can't widen its own
    rel_tol to sneak a regression through."""
    cur = _valid()
    cur["headline"]["p99_ms"] = {"value": 70.0, "better": "lower",
                                 "rel_tol": 0.99}
    regressions, _ = compare_headline(cur, _valid(), "x.json")
    assert any("p99_ms" in r for r in regressions)


# --------------------------------------------------- repo + CLI integration

def test_committed_bench_artifacts_validate():
    """Every BENCH_*.json actually in the repo satisfies the v2 schema."""
    problems = []
    for path in sorted(ROOT.glob("BENCH_*.json")):
        problems += validate_schema(json.loads(path.read_text()), path.name)
    assert problems == []


def test_check_walks_a_directory(tmp_path):
    (tmp_path / "BENCH_1_demo.json").write_text(json.dumps(_valid()))
    problems, _ = check(tmp_path, compare=False)
    assert problems == []
    (tmp_path / "BENCH_2_bad.json").write_text("{not json")
    problems, _ = check(tmp_path, compare=False)
    assert any("BENCH_2_bad" in p for p in problems)


def test_cli_smoke():
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_bench.py"),
         "--no-compare"],
        capture_output=True, text=True, cwd=ROOT, timeout=60)
    assert out.returncode == 0, out.stderr
