"""Data pipeline, optimizer, checkpoint manager, collectives codecs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticTokenPipeline
from repro.dist.collectives import (
    ErrorFeedback, dequantize_int8, ef_compress, quantize_int8,
)
from repro.optim import AdamW, AdamWConfig, cosine_schedule


# ------------------------------------------------------------------------ data

def test_data_deterministic():
    p = SyntheticTokenPipeline(256, 32, 8, seed=3)
    np.testing.assert_array_equal(p.global_batch_at(5), p.global_batch_at(5))
    assert not np.array_equal(p.global_batch_at(5), p.global_batch_at(6))


def test_data_host_sharding_consistent():
    """Concatenated host slices == the global batch, for any host count."""
    p = SyntheticTokenPipeline(256, 16, 8, seed=1)
    g = p.global_batch_at(3)
    for n_hosts in (1, 2, 4, 8):
        parts = [p.host_batch_at(3, h, n_hosts) for h in range(n_hosts)]
        np.testing.assert_array_equal(np.concatenate(parts, axis=0), g)


def test_data_has_learnable_structure():
    p = SyntheticTokenPipeline(64, 128, 4, seed=0, noise=0.0)
    toks = p.global_batch_at(0)
    chain = p._chain()
    hits = (chain[toks[:, :-1]] == toks[:, 1:]).mean()
    assert hits > 0.95


# ----------------------------------------------------------------------- optim

def test_adamw_converges_quadratic():
    opt = AdamW(AdamWConfig(peak_lr=0.1, warmup=5, total_steps=100,
                            weight_decay=0.0))
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(100):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_state_dtypes_converge(state_dtype):
    opt = AdamW(AdamWConfig(peak_lr=0.1, warmup=5, total_steps=120,
                            weight_decay=0.0, state_dtype=state_dtype))
    params = {"w": jnp.linspace(-2, 2, 16)}
    state = opt.init(params)
    for _ in range(120):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5, state_dtype


def test_state_specs_match_state_tree():
    from repro.dist.sharding import abstract_state
    opt = AdamW(AdamWConfig(state_dtype="int8"))
    params = {"a": jnp.ones((8, 4)), "b": jnp.ones((3,))}
    state = opt.init(params)
    specs = opt.state_specs({
        "a": _pspec((8, 4)), "b": _pspec((3,)),
    })
    sds = abstract_state(specs)
    assert jax.tree.structure(sds) == jax.tree.structure(
        jax.tree.map(lambda x: 0, state))
    flat_s = jax.tree.leaves(sds)
    flat_r = jax.tree.leaves(state)
    for s, r in zip(flat_s, flat_r):
        assert s.shape == r.shape and s.dtype == r.dtype


def _pspec(shape):
    from repro.models.layers import ParamSpec
    return ParamSpec(shape, jnp.float32, (None,) * len(shape),
                     lambda k, s, d: jnp.zeros(s, d))


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # peak at end of warmup
    assert lrs[-1] < 0.2                      # decayed


# ------------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"params": {"w": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16)},
            "step": jnp.int32(7)}
    for step in (10, 20, 30):
        mgr.save(step, tree)
    assert mgr.steps() == [20, 30]           # retention pruned step 10
    back = mgr.restore()
    np.testing.assert_array_equal(np.asarray(back["params"]["w"], np.float32),
                                  np.asarray(tree["params"]["w"], np.float32))


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir from a killed save must not be visible as a checkpoint."""
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, {"w": jnp.ones(3)})
    (tmp_path / "step_0000000009.tmp").mkdir()
    assert mgr.latest_step() == 5


# ----------------------------------------------------------------- collectives

@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=64))
def test_property_quantize_roundtrip_bounded(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(back - x))) <= amax / 127.0 + 1e-6


def test_error_feedback_drives_residual_transmission():
    """Sum of transmitted (decoded) values converges to the true sum of grads:
    with EF the residual is bounded, without it the bias accumulates."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=256).astype(np.float32))
    ef = ErrorFeedback.init(g)
    sent = jnp.zeros_like(g)
    for _ in range(50):
        q, s, ef = ef_compress(g, ef)
        sent = sent + dequantize_int8(q, s)
    # average transmitted value per step ~ g (residual bounded)
    err = float(jnp.max(jnp.abs(sent / 50 - g)))
    assert err < float(jnp.max(jnp.abs(g))) / 100.0
    assert float(jnp.max(jnp.abs(ef.residual))) < float(jnp.max(jnp.abs(g)))
