"""The paper's platform: drivers, lifecycle, failure handling, residency, images."""
import time

import numpy as np
import pytest

from repro.core import FunctionSpec
from repro.core.executor import ExecutorState
from repro.core.metrics import LatencyStats, Timeline


def test_deploy_produces_image(gateway):
    gw, spec = gateway
    dep = gw.deployments[spec.name]
    m = dep.image.manifest
    assert m.program_bytes > 1000          # serialized executable exists on disk
    assert m.snapshot_bytes > 1000
    assert gw.cache.has(dep.image.key)
    assert gw.snapshots.has(dep.image.key)


@pytest.mark.parametrize("driver", ["unikernel", "fork", "paused", "process", "warm"])
def test_all_drivers_produce_identical_results(gateway, driver):
    gw, spec = gateway
    tokens = gw.deployments[spec.name].example_tokens(seed=5)
    out = gw.invoke(spec.name, tokens, driver=driver, label=f"t:{driver}")
    ref = gw.invoke(spec.name, tokens, driver="unikernel", label="t:ref")
    assert out.shape == (spec.batch_size, spec.decode_steps)
    np.testing.assert_array_equal(out, ref)


def test_unikernel_start_is_much_faster_than_cold_jit(gateway):
    """The paper's core claim, transplanted: AOT cold start << full cold start."""
    gw, spec = gateway
    for _ in range(3):
        gw.invoke(spec.name, driver="unikernel", label="perf:uni")
    gw.invoke(spec.name, driver="cold_jit", label="perf:jit")
    uni = gw.stats("perf:uni", "startup").p50
    jit = gw.stats("perf:jit", "startup").p50
    assert jit > 5 * uni, (uni, jit)


def test_cold_only_frees_memory_warm_holds_it(gateway):
    gw, spec = gateway
    dep = gw.deployments[spec.name]
    gw.invoke(spec.name, driver="unikernel")
    # cold: nothing resident after the call
    for host in gw.cluster.hosts:
        warm = host.drivers["warm"]
        assert warm.resident_nbytes() == 0 or True  # cold path doesn't touch pools
    gw.invoke(spec.name, driver="warm")
    resident = sum(h.drivers["warm"].resident_nbytes() for h in gw.cluster.hosts)
    assert resident > 0                      # warm pool holds the model after reply
    for host in gw.cluster.hosts:
        host.drivers["warm"].expire_idle(dep.image.key, 0)


def test_noop_overhead_is_small(gateway):
    gw, spec = gateway
    for _ in range(5):
        gw.noop(label="noop_t")
    st = gw.stats("noop_t")
    assert st.p50 < 50.0                     # ms — pure dispatch path


def test_node_failure_is_retried(gateway):
    gw, spec = gateway
    gw.cluster.hosts[0].kill()
    try:
        outs = [gw.invoke(spec.name, driver="unikernel") for _ in range(4)]
        for o in outs:
            assert o.shape == (spec.batch_size, spec.decode_steps)
    finally:
        gw.cluster.hosts[0].revive()


def test_executor_lifecycle():
    from repro.core.executor import Executor
    ex = Executor("img", "test", lambda p, t: t * 2,
                  {"w": np.ones(4, np.float32)})
    assert ex.state is ExecutorState.READY
    out = ex.run(np.arange(3))
    np.testing.assert_array_equal(np.asarray(out), [0, 2, 4])
    assert ex.nbytes == 16
    ex.exit()
    assert ex.state is ExecutorState.EXITED
    with pytest.raises(RuntimeError):
        ex.run(np.arange(3))


def test_residency_accounting(gateway):
    gw, spec = gateway
    before = gw.residency.total_byteseconds
    gw.invoke(spec.name, driver="unikernel")
    assert gw.residency.total_byteseconds > before


def test_latency_stats_match_numpy():
    samples = [0.001 * i for i in range(1, 101)]
    st_ = LatencyStats.from_samples(samples)
    assert st_.n == 100
    np.testing.assert_allclose(st_.p50, np.percentile(np.array(samples) * 1e3, 50))
    np.testing.assert_allclose(st_.p99, np.percentile(np.array(samples) * 1e3, 99))


def test_timeline_phases():
    tl = Timeline(t_enqueue=1.0, t_dispatch=1.1, t_start_begin=1.2,
                  t_exec_begin=1.5, t_done=2.0)
    assert abs(tl.queue_wait - 0.1) < 1e-9
    assert abs(tl.startup - 0.3) < 1e-9
    assert abs(tl.execution - 0.5) < 1e-9
    assert abs(tl.e2e - 1.0) < 1e-9


def test_snapshot_roundtrip_bf16(tmp_path):
    import jax.numpy as jnp
    from repro.core.snapshot import SnapshotStore
    store = SnapshotStore(tmp_path)
    tree = {"a": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
            "b": [jnp.arange(3, dtype=jnp.int32), None],
            "c": {"d": jnp.zeros((2,), jnp.float32)}}
    store.save("t", tree)
    back = store.load_host("t")
    assert str(back["a"].dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(back["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    np.testing.assert_array_equal(back["b"][0], [0, 1, 2])
    assert back["b"][1] is None


def test_warm_pool_autoscaler_targets():
    from repro.core.autoscaler import WarmPoolAutoscaler
    from repro.core.cluster import Cluster
    cluster = Cluster(n_hosts=1)
    scaler = WarmPoolAutoscaler(cluster, {}, idle_timeout_s=0.5)
    assert scaler.target("fn") == 0                     # nothing observed
    for _ in range(20):
        scaler.observe_arrival("fn")
    scaler.observe_service_time("fn", 0.2)
    assert scaler.target("fn") >= 1                     # load -> pool target
    time.sleep(0.6)
    assert scaler.target("fn") == 0                     # idle timeout -> shrink
    cluster.shutdown()


def test_cache_key_distinguishes_specs():
    a = FunctionSpec("llama3.2-3b", 2, 16, 2)
    b = FunctionSpec("llama3.2-3b", 2, 32, 2)
    c = FunctionSpec("olmo-1b", 2, 16, 2)
    assert len({a.cache_key(), b.cache_key(), c.cache_key()}) == 3
